"""Pure-jnp oracles for the local SDDMM / SpMM / FusedMM kernels.

These are the ground truth the Pallas kernels are validated against
(assert_allclose over shape/dtype sweeps) and the portable fallback used
on backends without Pallas support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sparse import PaddedCOO, RowTiledCOO


# --- flat-COO oracles -------------------------------------------------------

def sddmm_coo(A: jax.Array, B: jax.Array, rows: jax.Array, cols: jax.Array,
              vals: jax.Array) -> jax.Array:
    """out[k] = vals[k] * <A[rows[k]], B[cols[k]]> (f32 accumulation)."""
    a = A[rows].astype(jnp.float32)
    b = B[cols].astype(jnp.float32)
    return (vals.astype(jnp.float32) * jnp.sum(a * b, axis=-1)).astype(vals.dtype)


def spmm_coo(rows: jax.Array, cols: jax.Array, vals: jax.Array,
             B: jax.Array, m: int) -> jax.Array:
    """out[m, r] with out[rows[k]] += vals[k] * B[cols[k]]."""
    contrib = vals[:, None].astype(jnp.float32) * B[cols].astype(jnp.float32)
    out = jnp.zeros((m, B.shape[-1]), jnp.float32)
    return out.at[rows].add(contrib).astype(B.dtype)


def fusedmm_coo(A: jax.Array, B: jax.Array, rows: jax.Array,
                cols: jax.Array, vals: jax.Array, m: int):
    """FusedMMA: (SpMMA(SDDMM(A,B,S), B), sddmm_vals)."""
    r_vals = sddmm_coo(A, B, rows, cols, vals)
    out = spmm_coo(rows, cols, r_vals, B, m)
    return out, r_vals


# --- RowTiledCOO oracles ----------------------------------------------------

def _flat(S: RowTiledCOO):
    return (S.rows_global().reshape(-1), S.cols.reshape(-1),
            S.vals.reshape(-1))


def sddmm(A: jax.Array, B: jax.Array, S: RowTiledCOO) -> RowTiledCOO:
    rows, cols, vals = _flat(S)
    out = sddmm_coo(A, B, rows, cols, vals)
    return S.with_vals(out.reshape(S.vals.shape))


def spmm(S: RowTiledCOO, B: jax.Array, m: int | None = None) -> jax.Array:
    rows, cols, vals = _flat(S)
    return spmm_coo(rows, cols, vals, B, m if m is not None else S.shape[0])


def fusedmm(A: jax.Array, B: jax.Array, S: RowTiledCOO,
            m: int | None = None):
    rows, cols, vals = _flat(S)
    out, r_vals = fusedmm_coo(A, B, rows, cols, vals,
                              m if m is not None else S.shape[0])
    return out, S.with_vals(r_vals.reshape(S.vals.shape))


# --- dense whole-matrix oracles (for end-to-end checks) ---------------------

def sddmm_dense(A, B, S_dense):
    return S_dense * (A @ B.T)


def spmm_dense(S_dense, B):
    return S_dense @ B


def fusedmm_dense(A, B, S_dense):
    R = sddmm_dense(A, B, S_dense)
    return R @ B, R
