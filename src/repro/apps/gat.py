"""Graph Attention Network forward pass (paper §VI-E).

Single-head GAT layer over adjacency S:

    e_ij  = LeakyReLU( <a1, W h_i> + <a2, W h_j> )   at nnz(S)
    Shat  = row_softmax(e)
    h'_i  = sigma( sum_j Shat_ij (W h)_j )

The paper notes the additive score is "a slight modification of Eq. 1 with
an identical communication pattern to SDDMM": with augmented embeddings
A* = [u, 1] and B* = [1, v] the dot <A*_i, B*_j> = u_i + v_j, so the score
computation IS an r=2 SDDMM through the repro kernels, and the aggregation
is an SpMM — per the paper, local kernel fusion is NOT applicable because
the softmax needs completed rows (noted in Fig. 9).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparse
from repro.kernels import ops


def leaky_relu(x, slope=0.2):
    return jnp.where(x >= 0, x, slope * x)


def attention_scores(S_ones: sparse.RowTiledCOO, u, v):
    """e_ij = u_i + v_j at nonzeros, via the r=2 SDDMM trick."""
    A_star = jnp.stack([u, jnp.ones_like(u)], axis=1)     # (m, 2)
    B_star = jnp.stack([jnp.ones_like(v), v], axis=1)     # (n, 2)
    return ops.sddmm(A_star, B_star, S_ones)


def row_softmax(S: sparse.RowTiledCOO) -> sparse.RowTiledCOO:
    """Softmax over each row's nonzero values (sparse, numerically safe)."""
    rows = S.rows_global().reshape(-1)
    vals = S.vals.reshape(-1)
    mask = vals != 0
    neg = jnp.full((S.shape[0],), -1e30, jnp.float32)
    rmax = neg.at[rows].max(jnp.where(mask, vals, -1e30))
    ex = jnp.where(mask, jnp.exp(vals - rmax[rows]), 0.0)
    rsum = jnp.zeros((S.shape[0],), jnp.float32).at[rows].add(ex)
    out = ex / jnp.maximum(rsum[rows], 1e-30)
    return S.with_vals(out.reshape(S.vals.shape))


@dataclasses.dataclass
class GATParams:
    W: jax.Array       # (d_in, d_out)
    a1: jax.Array      # (d_out,)
    a2: jax.Array      # (d_out,)


def init_gat_layer(key, d_in, d_out):
    k1, k2, k3 = jax.random.split(key, 3)
    return GATParams(
        W=jax.random.normal(k1, (d_in, d_out)) * (1.0 / np.sqrt(d_in)),
        a1=jax.random.normal(k2, (d_out,)) * 0.1,
        a2=jax.random.normal(k3, (d_out,)) * 0.1)


def gat_layer(S_ones: sparse.RowTiledCOO, H, p: GATParams,
              n_heads: int = 1, activation=jax.nn.elu):
    """Multi-head = independent heads on column slices of W, concatenated."""
    d_out = p.W.shape[1] // n_heads
    outs = []
    for h in range(n_heads):
        Wh = H @ p.W[:, h * d_out:(h + 1) * d_out]
        u = Wh @ p.a1[h * d_out:(h + 1) * d_out]
        v = Wh @ p.a2[h * d_out:(h + 1) * d_out]
        e = attention_scores(S_ones, u, v)
        e = e.with_vals(jnp.where(e.vals != 0, leaky_relu(e.vals), 0.0))
        Shat = row_softmax(e)
        outs.append(ops.spmm(Shat, Wh, m=S_ones.shape[0]))
    return activation(jnp.concatenate(outs, axis=1))


def gat_forward(S_ones, H0, layers, n_heads=1):
    H = H0
    for p in layers:
        H = gat_layer(S_ones, H, p, n_heads=n_heads)
    return H


def make_graph(n_nodes, nnz_per_row, seed=0, row_tile=128, nz_block=128):
    rows, cols, _ = sparse.erdos_renyi(n_nodes, n_nodes, nnz_per_row,
                                       seed=seed)
    # add self loops (standard GAT practice) and unit values
    rows = np.concatenate([rows, np.arange(n_nodes, dtype=np.int32)])
    cols = np.concatenate([cols, np.arange(n_nodes, dtype=np.int32)])
    key = np.unique(rows.astype(np.int64) * n_nodes + cols)
    rows = (key // n_nodes).astype(np.int32)
    cols = (key % n_nodes).astype(np.int32)
    vals = np.ones(len(rows), np.float32)
    return sparse.pack_row_tiled(rows, cols, vals, (n_nodes, n_nodes),
                                 row_tile=row_tile, nz_block=nz_block)
