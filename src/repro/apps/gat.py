"""Graph Attention Network forward pass (paper §VI-E).

Single-head GAT layer over adjacency S:

    e_ij  = LeakyReLU( <a1, W h_i> + <a2, W h_j> )   at nnz(S)
    Shat  = row_softmax(e)
    h'_i  = sigma( sum_j Shat_ij (W h)_j )

The paper notes the additive score is "a slight modification of Eq. 1 with
an identical communication pattern to SDDMM": with augmented embeddings
A* = [u, 1] and B* = [1, v] the dot <A*_i, B*_j> = u_i + v_j, so the score
computation IS an r=2 SDDMM through the repro kernels, and the aggregation
is an SpMM — per the paper, local kernel fusion is NOT applicable because
the softmax needs completed rows (noted in Fig. 9).  This is an
*application-level* barrier, distinct from the per-family elision matrix
of docs/algorithms.md: even on d15, whose FusedMM has a true fused cell,
GAT must run the two kernels separately — the elision grid applies to
FusedMM calls (ALS's matvecs), not to sddmm;softmax;spmm pipelines.

The distributed path (`gat_layer_distributed`) runs the score SDDMM and
the aggregation SpMM through `repro.core.api` on any registered
algorithm.  Between the two kernels the row softmax is applied on
*completed rows*, exactly as Fig. 9 requires: the sampled scores are
collected into the problem's home COO order (each row's nonzeros
complete — in the 1.5D sparse-shifting layout each processor's home
block already holds full rows; host assembly generalizes this to all
four families), softmaxed per row, and re-injected as the SpMM's sample
values.

The trainable path (`gat_layer_trainable` / `train_gat_distributed`)
is the same pipeline through the differentiable `repro.core.grads`
entrypoints: the score SDDMM's backward is the dual SpMM pair, the
aggregation SpMM takes the softmaxed attention as a differentiable
*values* input (its backward is the dual SDDMM on the adjacency
pattern), and `jax.grad` flows end-to-end to the layer parameters.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api, grads, sparse
from repro.distributed import elastic, faults
from repro.kernels import ops
from repro.training import checkpoint


def leaky_relu(x, slope=0.2):
    return jnp.where(x >= 0, x, slope * x)


def attention_scores(S_ones: sparse.RowTiledCOO, u, v):
    """e_ij = u_i + v_j at nonzeros, via the r=2 SDDMM trick."""
    A_star = jnp.stack([u, jnp.ones_like(u)], axis=1)     # (m, 2)
    B_star = jnp.stack([jnp.ones_like(v), v], axis=1)     # (n, 2)
    return ops.sddmm(A_star, B_star, S_ones)


def row_softmax(S: sparse.RowTiledCOO) -> sparse.RowTiledCOO:
    """Softmax over each row's nonzero values (sparse, numerically safe)."""
    rows = S.rows_global().reshape(-1)
    vals = S.vals.reshape(-1)
    mask = vals != 0
    neg = jnp.full((S.shape[0],), -1e30, jnp.float32)
    rmax = neg.at[rows].max(jnp.where(mask, vals, -1e30))
    ex = jnp.where(mask, jnp.exp(vals - rmax[rows]), 0.0)
    rsum = jnp.zeros((S.shape[0],), jnp.float32).at[rows].add(ex)
    out = ex / jnp.maximum(rsum[rows], 1e-30)
    return S.with_vals(out.reshape(S.vals.shape))


@dataclasses.dataclass
class GATParams:
    W: jax.Array       # (d_in, d_out)
    a1: jax.Array      # (d_out,)
    a2: jax.Array      # (d_out,)


def init_gat_layer(key, d_in, d_out):
    k1, k2, k3 = jax.random.split(key, 3)
    return GATParams(
        W=jax.random.normal(k1, (d_in, d_out)) * (1.0 / np.sqrt(d_in)),
        a1=jax.random.normal(k2, (d_out,)) * 0.1,
        a2=jax.random.normal(k3, (d_out,)) * 0.1)


def gat_layer(S_ones: sparse.RowTiledCOO, H, p: GATParams,
              n_heads: int = 1, activation=jax.nn.elu):
    """Multi-head = independent heads on column slices of W, concatenated."""
    d_out = p.W.shape[1] // n_heads
    outs = []
    for h in range(n_heads):
        Wh = H @ p.W[:, h * d_out:(h + 1) * d_out]
        u = Wh @ p.a1[h * d_out:(h + 1) * d_out]
        v = Wh @ p.a2[h * d_out:(h + 1) * d_out]
        e = attention_scores(S_ones, u, v)
        e = e.with_vals(jnp.where(e.vals != 0, leaky_relu(e.vals), 0.0))
        Shat = row_softmax(e)
        outs.append(ops.spmm(Shat, Wh, m=S_ones.shape[0]))
    return activation(jnp.concatenate(outs, axis=1))


def gat_forward(S_ones, H0, layers, n_heads=1):
    H = H0
    for p in layers:
        H = gat_layer(S_ones, H, p, n_heads=n_heads)
    return H


def graph_coo(n_nodes, nnz_per_row, seed=0):
    """ER adjacency + self loops (standard GAT practice), unit values."""
    rows, cols, _ = sparse.erdos_renyi(n_nodes, n_nodes, nnz_per_row,
                                       seed=seed)
    rows = np.concatenate([rows, np.arange(n_nodes, dtype=np.int32)])
    cols = np.concatenate([cols, np.arange(n_nodes, dtype=np.int32)])
    key = np.unique(rows.astype(np.int64) * n_nodes + cols)
    rows = (key // n_nodes).astype(np.int32)
    cols = (key % n_nodes).astype(np.int32)
    return rows, cols, np.ones(len(rows), np.float32)


def make_graph(n_nodes, nnz_per_row, seed=0, row_tile=128, nz_block=128):
    rows, cols, vals = graph_coo(n_nodes, nnz_per_row, seed=seed)
    return sparse.pack_row_tiled(rows, cols, vals, (n_nodes, n_nodes),
                                 row_tile=row_tile, nz_block=nz_block)


# ---------------------------------------------------------------------------
# Distributed path: score SDDMM + aggregation SpMM through repro.core.api,
# row softmax on completed rows in between (paper Fig. 9)
# ---------------------------------------------------------------------------

def make_dist_graph(n_nodes, nnz_per_row, r, *, algorithm="auto", c=None,
                    devices=None, seed=0, row_tile=32,
                    nz_block=32) -> api.DistProblem:
    """Adjacency as a DistProblem; ``r`` is the per-head output width the
    aggregation SpMM will run at (must obey the family's r-divisibility)."""
    rows, cols, vals = graph_coo(n_nodes, nnz_per_row, seed=seed)
    return api.make_problem(rows, cols, vals, (n_nodes, n_nodes), r,
                            algorithm=algorithm, c=c, devices=devices,
                            row_tile=row_tile, nz_block=nz_block)


def row_softmax_coo(rows, vals, n_rows):
    """Numerically-safe softmax over each row's nonzeros, COO layout.

    Operates on completed rows: every nonzero of a row must be present
    (the api's home-COO assembly guarantees this for all four families).
    """
    vals = np.asarray(vals, np.float64)
    rmax = np.full(n_rows, -np.inf)
    np.maximum.at(rmax, rows, vals)
    ex = np.exp(vals - np.where(np.isfinite(rmax), rmax, 0.0)[rows])
    rsum = np.zeros(n_rows)
    np.add.at(rsum, rows, ex)
    return (ex / np.maximum(rsum[rows], 1e-30)).astype(np.float32)


def gat_layer_distributed(graphP: api.DistProblem, H, p: GATParams,
                          n_heads: int = 1, activation=jax.nn.elu):
    """Distributed single layer, mirroring gat_layer head for head.

    Per head: (1) score SDDMM via the augmented r=2 trick, zero-padded to
    the family's minimum feasible width (padding columns contribute 0 to
    every dot product); (2) LeakyReLU + row softmax on the completed-row
    COO; (3) aggregation SpMM with the softmaxed attention as the sample
    values.  No local fusion — the softmax barrier between the kernels is
    exactly why (Fig. 9).
    """
    H = np.asarray(H, np.float32)
    n = graphP.m
    d_out = p.W.shape[1] // n_heads
    mult = graphP.alg.min_r_multiple(graphP.grid)
    r_score = max(2, ((2 + mult - 1) // mult) * mult)
    scoreP = graphP.with_r(r_score)
    aggP = graphP if graphP.r == d_out else graphP.with_r(d_out)
    W = np.asarray(p.W)
    a1, a2 = np.asarray(p.a1), np.asarray(p.a2)
    outs = []
    for h in range(n_heads):
        Wh = H @ W[:, h * d_out:(h + 1) * d_out]
        u = Wh @ a1[h * d_out:(h + 1) * d_out]
        v = Wh @ a2[h * d_out:(h + 1) * d_out]
        A_star = np.zeros((n, r_score), np.float32)
        B_star = np.zeros((n, r_score), np.float32)
        A_star[:, 0], A_star[:, 1] = u, 1.0
        B_star[:, 0], B_star[:, 1] = 1.0, v
        e = scoreP.sddmm(A_star, B_star).values()      # completed rows
        e = np.asarray(leaky_relu(e))
        attn = row_softmax_coo(graphP.rows, e, n)
        outs.append(aggP.with_values(attn).spmm(Wh))
    return activation(jnp.concatenate([jnp.asarray(o) for o in outs],
                                      axis=1))


def gat_forward_distributed(graphP: api.DistProblem, H0, layers,
                            n_heads: int = 1):
    H = H0
    for p in layers:
        H = gat_layer_distributed(graphP, H, p, n_heads=n_heads)
    return H


# ---------------------------------------------------------------------------
# Query mode: the same layer served through repro.serving, many clients'
# node queries coalesced per tick (docs/serving.md)
# ---------------------------------------------------------------------------

def gat_deploy_layer(pool, rows, cols, n_nodes, H, p: GATParams, *,
                     head: int = 0, n_heads: int = 1,
                     algorithm: str = "auto", c=None, devices=None,
                     comm: str = "dense", row_tile: int = 32,
                     nz_block: int = 32):
    """Deploy one GAT head for serving: graph + precomputed operands.

    At inference the parameters are frozen, so everything stationary is
    computed once and deployed with the graph: ``Wh`` (the projected
    embeddings the aggregation SpMM consumes) and the augmented score
    operands ``A* = [u, 1]`` / ``B* = [1, v]`` whose r=2 SDDMM yields
    the additive attention logits.  Every client query then moves only
    coordinates and attention values — the deployment's Session serves
    the operand replication from cache tick after tick.
    """
    H = np.asarray(H, np.float32)
    d_out = p.W.shape[1] // n_heads
    W = np.asarray(p.W)[:, head * d_out:(head + 1) * d_out]
    a1 = np.asarray(p.a1)[head * d_out:(head + 1) * d_out]
    a2 = np.asarray(p.a2)[head * d_out:(head + 1) * d_out]
    Wh = H @ W
    u, v = Wh @ a1, Wh @ a2
    A_star = np.zeros((n_nodes, 2), np.float32)
    B_star = np.zeros((n_nodes, 2), np.float32)
    A_star[:, 0], A_star[:, 1] = u, 1.0
    B_star[:, 0], B_star[:, 1] = 1.0, v
    return pool.deploy(rows, cols, np.ones(len(rows), np.float32),
                       (n_nodes, n_nodes), d_out,
                       operands={"A": A_star, "B": B_star, "Wh": Wh},
                       algorithm=algorithm, c=c, devices=devices,
                       comm=comm, row_tile=row_tile, nz_block=nz_block)


def gat_query_edges(deployment, node_ids):
    """The deployed graph's edges leaving ``node_ids`` (host COO order) —
    a served query's score pattern."""
    prob = deployment.problem
    node_ids = np.unique(np.asarray(node_ids).reshape(-1))
    mask = np.isin(prob.rows, node_ids)
    if not mask.any():
        raise ValueError("queried nodes have no outgoing edges")
    return prob.rows[mask], prob.cols[mask], mask


def gat_submit_scores(engine, deployment, node_ids, *,
                      arrival: float = 0.0):
    """Phase 1 of a served GAT query: queue the edge-score SDDMM for the
    edges leaving ``node_ids``.  All clients' phase-1 tickets share the
    deployed ``A``/``B`` operands, so a tick's worth of them coalesces
    into ONE union-of-patterns round."""
    erows, ecols, _ = gat_query_edges(deployment, node_ids)
    ticket = engine.submit_score(deployment, erows, ecols, "A", "B",
                                 arrival=arrival)
    return ticket, erows


def gat_submit_aggregate(engine, deployment, node_ids, scores, *,
                         arrival: float = 0.0):
    """Phase 2: LeakyReLU + row softmax on the completed queried rows
    (the Fig. 9 barrier, now per client), then the aggregation SpMM
    with the client's attention as a per-request values override (zero
    outside the queried rows — a row of the SpMM output reads only its
    own row's values, so the queried rows are exact)."""
    prob = deployment.problem
    erows, _, mask = gat_query_edges(deployment, node_ids)
    e = np.asarray(leaky_relu(jnp.asarray(np.asarray(scores))))
    attn = row_softmax_coo(erows, e, prob.m)
    vals = np.zeros(prob.nnz, np.float32)
    vals[mask] = attn
    return engine.submit_aggregate(deployment, deployment.operand("Wh"),
                                   vals=vals, arrival=arrival)


def gat_layer_served(engine, deployment, node_ids,
                     activation=jax.nn.elu):
    """Single-client convenience: run both phases through the engine
    (one tick each) and return the layer output rows for ``node_ids``.
    Matches :func:`gat_layer_distributed`'s rows bitwise for a one-head
    layer — same padded score width, same softmax, same aggregation."""
    node_ids = np.unique(np.asarray(node_ids).reshape(-1))
    t_score, _ = gat_submit_scores(engine, deployment, node_ids)
    engine.tick()
    t_agg = gat_submit_aggregate(engine, deployment, node_ids,
                                 t_score.result())
    engine.tick()
    return activation(jnp.asarray(t_agg.result()[node_ids]))


# ---------------------------------------------------------------------------
# Trainable path: the same pipeline through the differentiable
# repro.core.grads entrypoints, so jax.grad flows end-to-end
# ---------------------------------------------------------------------------

def segment_softmax(rows, vals, n_rows):
    """Differentiable row softmax over COO values (completed rows)."""
    rows = jnp.asarray(rows)
    rmax = jax.ops.segment_max(vals, rows, num_segments=n_rows)
    rmax = jnp.where(jnp.isfinite(rmax), rmax, 0.0)
    ex = jnp.exp(vals - rmax[rows])
    rsum = jax.ops.segment_sum(ex, rows, num_segments=n_rows)
    return ex / jnp.maximum(rsum[rows], 1e-30)


def gat_layer_trainable(graphP: api.DistProblem, H, W, a1, a2,
                        n_heads: int = 1, activation=jax.nn.elu,
                        session: api.Session | None = None):
    """Differentiable distributed GAT layer (jax.grad-able in W/a1/a2/H).

    Mirrors :func:`gat_layer_distributed` kernel for kernel, but every
    distributed call goes through :mod:`repro.core.grads`: the score
    SDDMM's backward is the dual SpMM pair, and the aggregation SpMM
    takes the softmaxed attention as a *differentiable values* input —
    its backward is the dual SDDMM on the adjacency pattern (this is
    where the gradient w.r.t. the attention scores flows).  The row
    softmax between the kernels runs on completed rows in the home COO
    order, exactly as the forward-only path does (paper Fig. 9: no
    local fusion across the softmax barrier, in either pass).
    """
    H = jnp.asarray(H, jnp.float32)
    n = graphP.m
    d_out = W.shape[1] // n_heads
    mult = graphP.alg.min_r_multiple(graphP.grid)
    r_score = max(2, ((2 + mult - 1) // mult) * mult)
    scoreP = graphP.with_r(r_score)
    aggP = graphP if graphP.r == d_out else graphP.with_r(d_out)
    outs = []
    for h in range(n_heads):
        Wh = H @ W[:, h * d_out:(h + 1) * d_out]
        u = Wh @ a1[h * d_out:(h + 1) * d_out]
        v = Wh @ a2[h * d_out:(h + 1) * d_out]
        A_star = jnp.zeros((n, r_score)).at[:, 0].set(u).at[:, 1].set(1.0)
        B_star = jnp.zeros((n, r_score)).at[:, 0].set(1.0).at[:, 1].set(v)
        e = grads.sddmm(scoreP, A_star, B_star, session=session)
        e = leaky_relu(e)
        attn = segment_softmax(graphP.rows, e, n)
        outs.append(grads.spmm(aggP, attn, Wh, session=session))
    return activation(jnp.concatenate(outs, axis=1))


def train_gat_distributed(graphP: api.DistProblem, H, target, *,
                          d_out: int | None = None, steps: int = 20,
                          lr: float = 0.05, n_heads: int = 1, seed: int = 0,
                          session: api.Session | None = None,
                          monitor=None, ckpt_dir: str | None = None,
                          ckpt_every: int = 5, max_retries: int = 2,
                          verbose: bool = True):
    """Gradient-based training of one distributed GAT layer.

    Minimizes the MSE between the layer output and ``target`` by SGD on
    (W, a1, a2), every kernel of every step a distributed primitive on
    ``graphP``'s grid.  Returns ((W, a1, a2), loss history); the history
    must be decreasing for any sane (lr, steps).

    Robustness wiring mirrors ``train_embedding_distributed``
    (docs/robustness.md): steps run under ``run_step_resilient`` with
    the typed retryable set — ``TransientFault`` invalidates the
    Session's replication for this grid and retries; ``DeviceLost``
    re-plans ``graphP`` onto a degraded mesh.  ``monitor`` times steps
    for straggler flagging; ``ckpt_dir`` checkpoints (W, a1, a2) plus
    the problem's :meth:`api.DistProblem.meta_dict` every ``ckpt_every``
    steps and resumes from the latest committed step, rebuilding packs
    via :func:`api.problem_from_meta` on whatever mesh is available.
    """
    H = jnp.asarray(H, jnp.float32)
    target = jnp.asarray(target, jnp.float32)
    session = session if session is not None else api.Session()
    d_in = H.shape[1]
    d_out = d_out if d_out is not None else target.shape[1]
    p0 = init_gat_layer(jax.random.PRNGKey(seed), d_in, d_out)
    params = (jnp.asarray(p0.W), jnp.asarray(p0.a1), jnp.asarray(p0.a2))

    def make_grad(prob):
        def loss_fn(params):
            W, a1, a2 = params
            out = gat_layer_trainable(prob, H, W, a1, a2, n_heads=n_heads,
                                      session=session)
            return jnp.mean((out - target) ** 2)
        return jax.value_and_grad(loss_fn)

    grad_fn = make_grad(graphP)

    start = 0
    if ckpt_dir is not None:
        last = checkpoint.latest_step(ckpt_dir)
        if last is not None:
            meta = checkpoint.load_manifest(ckpt_dir, last).get("meta")
            if meta is not None:
                # resume onto the mesh of the problem the caller handed
                # us — not the process's full device set
                devs = list(np.asarray(
                    graphP.grid.mesh.devices).reshape(-1))
                graphP = api.problem_from_meta(
                    meta, graphP.rows, graphP.cols, graphP.vals,
                    devices=devs)
                grad_fn = make_grad(graphP)
            tree = checkpoint.restore(
                ckpt_dir, last, {"W": params[0], "a1": params[1],
                                 "a2": params[2]})
            params = tuple(jnp.asarray(tree[k]) for k in ("W", "a1", "a2"))
            start = last
            if verbose:
                print(f"gat: resumed step {last} on "
                      f"{graphP.alg.name} p={graphP.p}")

    def on_failure(attempt, e):
        nonlocal graphP, grad_fn
        e = faults.unwrap(e)   # typed fault may be XLA-laundered
        session.invalidate(graphP)
        if isinstance(e, faults.DeviceLost):
            graphP = api.degrade(graphP, e.rank)
            grad_fn = make_grad(graphP)
            if verbose:
                print(f"gat: lost rank {e.rank} -> re-planned onto "
                      f"{graphP.alg.name} p={graphP.p}")

    hist = []
    for it in range(start, steps):
        def step(params):
            if monitor is not None:
                return monitor.timed(it, grad_fn, params)
            return grad_fn(params)

        val, gparams = elastic.run_step_resilient(
            step, None, None, params,
            max_retries=max_retries, on_failure=on_failure)
        params = tuple(p - lr * g for p, g in zip(params, gparams))
        hist.append(float(val))
        if verbose:
            print(f"gat[{graphP.alg.name}] step {it}: loss {val:.5f}")
        if ckpt_dir is not None and (it + 1) % ckpt_every == 0:
            checkpoint.save(ckpt_dir, it + 1,
                            {"W": np.asarray(params[0]),
                             "a1": np.asarray(params[1]),
                             "a2": np.asarray(params[2])},
                            meta=graphP.meta_dict())
    return params, hist
