"""Collaborative filtering via Alternating Least Squares (paper §VI-E).

Batched-CG formulation of Zhao & Canny [1]: solving the per-row normal
equations (B_Omega_i^T B_Omega_i + lambda I) a_i = B_Omega_i^T c_i for ALL
rows at once.  The batched matvec

    y_i = sum_{j in Omega_i} <x_i, b_j> b_j + lambda x_i

is exactly FusedMMA(mask, X, B) + lambda X — the paper's key observation —
so every CG iteration is one FusedMM call through the repro kernels.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparse
from repro.kernels import ops


@dataclasses.dataclass
class ALSProblem:
    S: sparse.RowTiledCOO        # mask/ratings (m x n), vals = ratings
    St: sparse.RowTiledCOO       # transpose pack (n x m)
    mask: sparse.RowTiledCOO     # S with vals=1 at nonzeros
    maskt: sparse.RowTiledCOO
    m: int
    n: int
    r: int
    reg: float = 0.1


def make_problem(m, n, nnz_per_row, r, seed=0, reg=0.1,
                 row_tile=128, nz_block=128) -> ALSProblem:
    rows, cols, vals = sparse.erdos_renyi(m, n, nnz_per_row, seed=seed)
    vals = np.abs(vals) + 0.5          # positive "ratings"
    ones = np.ones_like(vals)
    S = sparse.pack_row_tiled(rows, cols, vals, (m, n), row_tile=row_tile,
                              nz_block=nz_block)
    St = sparse.pack_row_tiled(cols, rows, vals, (n, m), row_tile=row_tile,
                               nz_block=nz_block)
    mask = S.with_vals(jnp.where(S.vals != 0, 1.0, 0.0))
    maskt = St.with_vals(jnp.where(St.vals != 0, 1.0, 0.0))
    return ALSProblem(S, St, mask, maskt, m, n, r, reg)


def fusedmm_matvec(mask, X, B, reg, m):
    """y = FusedMM(mask, X, B) + reg*X — one CG matvec for all rows."""
    out, _ = ops.fusedmm(X, B, mask, m=m)
    return out + reg * X


def cg_solve(mask, B, rhs, reg, m, iters=10):
    """Batched CG on the ALS normal equations (all rows at once)."""
    X = jnp.zeros_like(rhs)
    R = rhs - fusedmm_matvec(mask, X, B, reg, m)
    P = R
    rs = jnp.sum(R * R, axis=1, keepdims=True)
    for _ in range(iters):
        AP = fusedmm_matvec(mask, P, B, reg, m)
        alpha = rs / jnp.maximum(jnp.sum(P * AP, axis=1, keepdims=True),
                                 1e-12)
        X = X + alpha * P
        R = R - alpha * AP
        rs_new = jnp.sum(R * R, axis=1, keepdims=True)
        P = R + (rs_new / jnp.maximum(rs, 1e-12)) * P
        rs = rs_new
    return X


def als_round(prob: ALSProblem, A, B, cg_iters=10):
    """One ALS round: optimize A given B, then B given A."""
    rhs_a = ops.spmm(prob.S, B, m=prob.m)                  # SpMMA(C, B)
    A = cg_solve(prob.mask, B, rhs_a, prob.reg, prob.m, cg_iters)
    rhs_b = ops.spmm(prob.St, A, m=prob.n)                 # SpMMB(C, A)
    B = cg_solve(prob.maskt, A, rhs_b, prob.reg, prob.n, cg_iters)
    return A, B


def loss(prob: ALSProblem, A, B):
    """|| C - SDDMM(A, B, mask) ||_F^2 on observed entries."""
    pred = ops.sddmm(A, B, prob.mask)
    return float(jnp.sum((prob.S.vals - pred.vals) ** 2))


def run_als(m=1024, n=1024, nnz_per_row=8, r=32, rounds=3, cg_iters=10,
            seed=0, verbose=True):
    prob = make_problem(m, n, nnz_per_row, r, seed=seed)
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.standard_normal((m, r)) * 0.1, jnp.float32)
    B = jnp.asarray(rng.standard_normal((n, r)) * 0.1, jnp.float32)
    hist = [loss(prob, A, B)]
    for it in range(rounds):
        A, B = als_round(prob, A, B, cg_iters)
        hist.append(loss(prob, A, B))
        if verbose:
            print(f"ALS round {it}: loss {hist[-2]:.1f} -> {hist[-1]:.1f}")
    return A, B, hist
