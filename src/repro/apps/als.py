"""Collaborative filtering via Alternating Least Squares (paper §VI-E).

Batched-CG formulation of Zhao & Canny [1]: solving the per-row normal
equations (B_Omega_i^T B_Omega_i + lambda I) a_i = B_Omega_i^T c_i for ALL
rows at once.  The batched matvec

    y_i = sum_{j in Omega_i} <x_i, b_j> b_j + lambda x_i

is exactly FusedMMA(mask, X, B) + lambda X — the paper's key observation —
so every CG iteration is one FusedMM call through the repro kernels.

Two paths share the math:

* the single-device path (`run_als`) calls the local Pallas kernels;
* the distributed path (`run_als_distributed`) runs every kernel through
  `repro.core.api` — any registered algorithm, `algorithm="auto"` by
  default — and threads an `api.Session` through the CG loop, so the
  fiber replication of the *stationary* factor matrix is paid once per
  solve instead of once per iteration (the paper's replication-reuse
  elision extended across iterations).

`train_embedding_distributed` is the gradient-based sibling: SGD on the
sampled loss through the differentiable `repro.core.grads` entrypoints,
where each step's backward is the dual SpMM/SpMM-transpose pair on the
same grid and the Session replays the forward's replication.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api, grads, sparse
from repro.distributed import elastic, faults
from repro.kernels import ops
from repro.training import checkpoint


@dataclasses.dataclass
class ALSProblem:
    S: sparse.RowTiledCOO        # mask/ratings (m x n), vals = ratings
    St: sparse.RowTiledCOO       # transpose pack (n x m)
    mask: sparse.RowTiledCOO     # S with vals=1 at nonzeros
    maskt: sparse.RowTiledCOO
    m: int
    n: int
    r: int
    reg: float = 0.1


def make_problem(m, n, nnz_per_row, r, seed=0, reg=0.1,
                 row_tile=128, nz_block=128) -> ALSProblem:
    rows, cols, vals = sparse.erdos_renyi(m, n, nnz_per_row, seed=seed)
    vals = np.abs(vals) + 0.5          # positive "ratings"
    ones = np.ones_like(vals)
    S = sparse.pack_row_tiled(rows, cols, vals, (m, n), row_tile=row_tile,
                              nz_block=nz_block)
    St = sparse.pack_row_tiled(cols, rows, vals, (n, m), row_tile=row_tile,
                               nz_block=nz_block)
    mask = S.with_vals(jnp.where(S.vals != 0, 1.0, 0.0))
    maskt = St.with_vals(jnp.where(St.vals != 0, 1.0, 0.0))
    return ALSProblem(S, St, mask, maskt, m, n, r, reg)


def fusedmm_matvec(mask, X, B, reg, m):
    """y = FusedMM(mask, X, B) + reg*X — one CG matvec for all rows."""
    out, _ = ops.fusedmm(X, B, mask, m=m)
    return out + reg * X


def cg_solve(mask, B, rhs, reg, m, iters=10):
    """Batched CG on the ALS normal equations (all rows at once)."""
    X = jnp.zeros_like(rhs)
    R = rhs - fusedmm_matvec(mask, X, B, reg, m)
    P = R
    rs = jnp.sum(R * R, axis=1, keepdims=True)
    for _ in range(iters):
        AP = fusedmm_matvec(mask, P, B, reg, m)
        alpha = rs / jnp.maximum(jnp.sum(P * AP, axis=1, keepdims=True),
                                 1e-12)
        X = X + alpha * P
        R = R - alpha * AP
        rs_new = jnp.sum(R * R, axis=1, keepdims=True)
        P = R + (rs_new / jnp.maximum(rs, 1e-12)) * P
        rs = rs_new
    return X


def als_round(prob: ALSProblem, A, B, cg_iters=10):
    """One ALS round: optimize A given B, then B given A."""
    rhs_a = ops.spmm(prob.S, B, m=prob.m)                  # SpMMA(C, B)
    A = cg_solve(prob.mask, B, rhs_a, prob.reg, prob.m, cg_iters)
    rhs_b = ops.spmm(prob.St, A, m=prob.n)                 # SpMMB(C, A)
    B = cg_solve(prob.maskt, A, rhs_b, prob.reg, prob.n, cg_iters)
    return A, B


def loss(prob: ALSProblem, A, B):
    """|| C - SDDMM(A, B, mask) ||_F^2 on observed entries."""
    pred = ops.sddmm(A, B, prob.mask)
    return float(jnp.sum((prob.S.vals - pred.vals) ** 2))


# ---------------------------------------------------------------------------
# Distributed path: every kernel call through the unified repro.core.api
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DistALSProblem:
    """Ratings + mask problems in both orientations, one grid.

    A-solve matvecs run FusedMM on `mask`; B-solve matvecs on `mask_t`
    (the normal equations of the transposed system).  `ratings` /
    `ratings_t` supply the right-hand sides via SpMM.
    """
    ratings: api.DistProblem
    ratings_t: api.DistProblem
    mask: api.DistProblem
    mask_t: api.DistProblem
    m: int
    n: int
    r: int
    reg: float = 0.1


def make_dist_problem(m, n, nnz_per_row, r, *, algorithm="auto", c=None,
                      devices=None, seed=0, reg=0.1, row_tile=32,
                      nz_block=32) -> DistALSProblem:
    """Distributed analogue of make_problem: one grid, four plans."""
    rows, cols, vals = sparse.erdos_renyi(m, n, nnz_per_row, seed=seed)
    vals = np.abs(vals) + 0.5
    ratings = api.make_problem(rows, cols, vals, (m, n), r,
                               algorithm=algorithm, c=c, devices=devices,
                               row_tile=row_tile, nz_block=nz_block)
    mask = ratings.with_values(np.ones_like(vals))
    return DistALSProblem(ratings, ratings.transposed(),
                          mask, mask.transposed(), m, n, r, reg)


def dist_fusedmm_matvec(maskP: api.DistProblem, X, B, reg,
                        session: api.Session | None = None,
                        elision: str = "auto"):
    """y = FusedMM(mask, X, B) + reg*X through the unified API."""
    out, _ = maskP.fusedmm(X, B, elision=elision, session=session)
    return out + reg * np.asarray(X, np.float32)


def dist_cg_solve(maskP: api.DistProblem, B, rhs, reg, iters=10,
                  session: api.Session | None = None,
                  elision: str = "auto"):
    """Batched CG with every matvec one distributed FusedMM call.

    B is stationary across the whole solve, so with a Session its fiber
    replication happens exactly once (first matvec); the iterate X
    changes every iteration and is replicated fresh — never stale.
    """
    rhs = np.asarray(rhs, np.float32)
    X = np.zeros_like(rhs)
    R = rhs - dist_fusedmm_matvec(maskP, X, B, reg, session, elision)
    P = R
    rs = np.sum(R * R, axis=1, keepdims=True)
    for _ in range(iters):
        AP = dist_fusedmm_matvec(maskP, P, B, reg, session, elision)
        alpha = rs / np.maximum(np.sum(P * AP, axis=1, keepdims=True),
                                1e-12)
        X = X + alpha * P
        R = R - alpha * AP
        rs_new = np.sum(R * R, axis=1, keepdims=True)
        P = R + (rs_new / np.maximum(rs, 1e-12)) * P
        rs = rs_new
    return X


def dist_als_round(dp: DistALSProblem, A, B, cg_iters=10,
                   session: api.Session | None = None,
                   elision: str = "auto"):
    """One distributed ALS round: optimize A given B, then B given A.

    ``elision`` pins the FusedMM strategy of every CG matvec (any cell
    the chosen family implements — see docs/algorithms.md); the default
    "auto" ranks the family's cells by their session-steady-state word
    counts, so the cached loop lands on the cheapest cell for the grid
    (docs/choosing.md's worked ALS example).
    """
    rhs_a = dp.ratings.spmm(B)
    A = dist_cg_solve(dp.mask, B, rhs_a, dp.reg, cg_iters, session,
                      elision)
    rhs_b = dp.ratings_t.spmm(A)
    B = dist_cg_solve(dp.mask_t, A, rhs_b, dp.reg, cg_iters, session,
                      elision)
    return A, B


def dist_loss(dp: DistALSProblem, A, B):
    """|| C - SDDMM(A, B, mask) ||_F^2 on observed entries."""
    pred = dp.mask.sddmm(A, B).values()
    return float(np.sum((dp.ratings.vals - pred) ** 2))


def run_als_distributed(m=1024, n=1024, nnz_per_row=8, r=32, rounds=3,
                        cg_iters=10, seed=0, algorithm="auto", c=None,
                        devices=None, elision="auto", verbose=True):
    """End-to-end distributed ALS: the §VI-E application on any
    registered algorithm, with Session-cached replication in the CG loop.
    ``elision`` selects the FusedMM cell for the matvecs ("auto" = the
    cost model's session-aware pick).
    """
    dp = make_dist_problem(m, n, nnz_per_row, r, seed=seed,
                           algorithm=algorithm, c=c, devices=devices)
    rng = np.random.default_rng(seed)
    A = (rng.standard_normal((m, r)) * 0.1).astype(np.float32)
    B = (rng.standard_normal((n, r)) * 0.1).astype(np.float32)
    session = api.Session()
    hist = [dist_loss(dp, A, B)]
    for it in range(rounds):
        A, B = dist_als_round(dp, A, B, cg_iters, session, elision)
        hist.append(dist_loss(dp, A, B))
        if verbose:
            print(f"ALS[{dp.mask.alg.name}] round {it}: "
                  f"loss {hist[-2]:.1f} -> {hist[-1]:.1f}")
    return A, B, hist


# ---------------------------------------------------------------------------
# Query mode: trained factors served through repro.serving — many
# clients' user-item score queries coalesced per tick (docs/serving.md)
# ---------------------------------------------------------------------------

def deploy_factors(pool, rows, cols, vals, shape, U, V, *,
                   algorithm: str = "auto", c=None, devices=None,
                   comm: str = "dense", row_tile: int = 32,
                   nz_block: int = 32):
    """Deploy trained CF factors for serving: the ratings graph plus the
    factor matrices ``U (m, r)`` / ``V (n, r)`` as stationary operands.

    The pool key digests the factors too, so re-deploying after a
    training refresh is a miss (fresh replication), while the identical
    deploy is a hit (warm Session).  Prediction traffic then moves only
    (user, item) coordinate lists.
    """
    U = np.asarray(U, np.float32)
    V = np.asarray(V, np.float32)
    if U.shape[1] != V.shape[1]:
        raise ValueError(f"factor widths differ: {U.shape} vs {V.shape}")
    return pool.deploy(rows, cols, vals, shape, U.shape[1],
                       operands={"U": U, "V": V}, algorithm=algorithm,
                       c=c, devices=devices, comm=comm,
                       row_tile=row_tile, nz_block=nz_block)


def predict_scores(engine, deployment, users, items, *,
                   arrival: float = 0.0):
    """Queue a prediction query: ``score_k = <U_users[k], V_items[k]>``.

    Exactly the paper's CF inference shape — an SDDMM sampled at the
    requested (user, item) pairs against the deployed factors.  Every
    prediction ticket shares the deployed operands, so a tick's worth
    of clients coalesces into ONE union-of-patterns SDDMM round.
    """
    return engine.submit_score(deployment, users, items, "U", "V",
                               arrival=arrival)


def lookup_embeddings(engine, deployment, weights, *,
                      arrival: float = 0.0):
    """Queue an embedding aggregation: ``out = ratings_graph @ weights``
    (``weights (n, w)``) — the neighborhood-lookup shape; all deployed-
    values lookups in a tick ride one batched-RHS SpMM round."""
    return engine.submit_aggregate(deployment, weights, arrival=arrival)


# ---------------------------------------------------------------------------
# Sampled-loss embedding training: SGD through the differentiable
# distributed kernels (repro.core.grads) — the gradient-based sibling of
# the ALS solver above, FusedMM forward AND backward every step
# ---------------------------------------------------------------------------

def sampled_loss(maskP: api.DistProblem, X, Y, targets, reg=0.0,
                 session: api.Session | None = None):
    """0.5 ||SDDMM(mask, X, Y) - targets||^2 on the observed entries.

    The graph-embedding / matrix-completion objective: only the sampled
    predictions ``<x_i, y_j>`` at nnz(mask) enter the loss, so both the
    forward and (via the dual primitives) the backward communicate like
    one SDDMM/SpMM pair — never a dense m x n matrix.
    """
    pred = grads.sddmm(maskP, X, Y, session=session)
    out = 0.5 * jnp.sum((pred - jnp.asarray(targets)) ** 2)
    if reg:
        out = out + 0.5 * reg * (jnp.sum(X * X) + jnp.sum(Y * Y))
    return out


def train_embedding_distributed(m=256, n=256, nnz_per_row=6, r=16,
                                steps=20, lr=0.05, seed=0,
                                algorithm="auto", c=None, devices=None,
                                reg=1e-4, rows=None, cols=None, vals=None,
                                monitor=None, ckpt_dir=None, ckpt_every=5,
                                max_retries=2, verbose=True):
    """End-to-end distributed embedding training by SGD on the sampled
    loss — every step one distributed SDDMM forward plus its dual
    SpMM/SpMM-transpose backward on the same grid, with an
    ``api.Session`` replaying the forward's replication in the backward.

    Pass explicit ``(rows, cols, vals)`` — all three, plus the matching
    ``m``/``n`` — to train on a real matrix (e.g. loaded via
    :func:`repro.core.mtx.load_mtx`); by default a seeded Erdos-Renyi
    ratings matrix is generated.  Returns ``(X, Y, hist)`` with a
    decreasing loss history.

    Robustness wiring (docs/robustness.md): every step runs under
    ``elastic.run_step_resilient`` — a ``TransientFault`` invalidates the
    Session's replication for this grid and retries; a ``DeviceLost``
    re-plans onto a degraded mesh via :func:`api.degrade` before
    retrying.  ``monitor`` (a :class:`elastic.StepMonitor`) times each
    step for straggler flagging.  With ``ckpt_dir`` the factors are
    checkpointed every ``ckpt_every`` steps alongside the problem's
    :meth:`api.DistProblem.meta_dict`, and training resumes from the
    latest committed step — rebuilding the packs via
    :func:`api.problem_from_meta` (same mesh -> pinned family/c; changed
    device count -> cost-model re-dispatch).
    """
    if rows is None:
        if cols is not None or vals is not None:
            raise ValueError("pass rows, cols and vals together")
        rows, cols, vals = sparse.erdos_renyi(m, n, nnz_per_row, seed=seed)
        vals = np.abs(vals) + 0.5
    else:
        if cols is None or vals is None:
            raise ValueError("pass rows, cols and vals together")
        if int(np.max(rows, initial=0)) >= m \
                or int(np.max(cols, initial=0)) >= n:
            raise ValueError(
                f"coordinates exceed shape ({m}, {n}) — pass the "
                "matrix's m/n alongside rows/cols/vals")
    maskP = api.make_problem(rows, cols, np.ones_like(vals, np.float32),
                             (m, n), r, algorithm=algorithm, c=c,
                             devices=devices)
    rng = np.random.default_rng(seed + 1)
    X = jnp.asarray(rng.standard_normal((m, r)) * 0.1, jnp.float32)
    Y = jnp.asarray(rng.standard_normal((n, r)) * 0.1, jnp.float32)
    targets = jnp.asarray(vals, jnp.float32)
    session = api.Session()

    def make_grad(prob):
        return jax.value_and_grad(
            lambda X, Y: sampled_loss(prob, X, Y, targets, reg, session),
            argnums=(0, 1))

    grad_fn = make_grad(maskP)

    start = 0
    if ckpt_dir is not None:
        last = checkpoint.latest_step(ckpt_dir)
        if last is not None:
            meta = checkpoint.load_manifest(ckpt_dir, last).get("meta")
            if meta is not None:
                maskP = api.problem_from_meta(
                    meta, rows, cols, np.ones_like(vals, np.float32),
                    devices=devices)
                grad_fn = make_grad(maskP)
            tree = checkpoint.restore(ckpt_dir, last, {"X": X, "Y": Y})
            X, Y = jnp.asarray(tree["X"]), jnp.asarray(tree["Y"])
            start = last
            if verbose:
                print(f"embed: resumed step {last} on "
                      f"{maskP.alg.name} p={maskP.p}")

    def on_failure(attempt, e):
        nonlocal maskP, grad_fn
        e = faults.unwrap(e)   # typed fault may be XLA-laundered
        session.invalidate(maskP)
        if isinstance(e, faults.DeviceLost):
            maskP = api.degrade(maskP, e.rank)
            grad_fn = make_grad(maskP)
            if verbose:
                print(f"embed: lost rank {e.rank} -> re-planned onto "
                      f"{maskP.alg.name} p={maskP.p}")

    hist = []
    for it in range(start, steps):
        def step(X, Y):
            if monitor is not None:
                return monitor.timed(it, grad_fn, X, Y)
            return grad_fn(X, Y)

        val, (gx, gy) = elastic.run_step_resilient(
            step, None, None, X, Y,
            max_retries=max_retries, on_failure=on_failure)
        X = X - lr * gx
        Y = Y - lr * gy
        hist.append(float(val))
        if verbose:
            print(f"embed[{maskP.alg.name}] step {it}: loss {val:.3f}")
        if ckpt_dir is not None and (it + 1) % ckpt_every == 0:
            checkpoint.save(ckpt_dir, it + 1,
                            {"X": np.asarray(X), "Y": np.asarray(Y)},
                            meta=maskP.meta_dict())
    return X, Y, hist


def run_als(m=1024, n=1024, nnz_per_row=8, r=32, rounds=3, cg_iters=10,
            seed=0, verbose=True):
    prob = make_problem(m, n, nnz_per_row, r, seed=seed)
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.standard_normal((m, r)) * 0.1, jnp.float32)
    B = jnp.asarray(rng.standard_normal((n, r)) * 0.1, jnp.float32)
    hist = [loss(prob, A, B)]
    for it in range(rounds):
        A, B = als_round(prob, A, B, cg_iters)
        hist.append(loss(prob, A, B))
        if verbose:
            print(f"ALS round {it}: loss {hist[-2]:.1f} -> {hist[-1]:.1f}")
    return A, B, hist
