"""Configuration system: model, parallelism, training, serving.

Every assigned architecture is a ``ModelConfig`` built in
``repro/configs/<arch>.py`` and registered under its id.  Layer stacks are
expressed as repeated SEGMENTS of heterogeneous super-blocks so that
``jax.lax.scan`` runs over the repetitions (HLO size independent of depth —
critical for 80-layer dry-run compiles) while hybrids like Jamba keep their
exact interleave.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str           # "attn" | "mamba"
    ffn: str             # "dense" | "moe" | "none"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense|moe|ssm|hybrid|vlm|audio
    d_model: int
    vocab: int
    # segments: ((layerspecs_in_superblock, repeat_count), ...)
    segments: Tuple[Tuple[Tuple[LayerSpec, ...], int], ...]
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0               # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope: str = "rope"              # "rope" | "mrope" | "none"
    rope_theta: float = 1e4
    causal: bool = True
    # dense ffn
    d_ff: int = 0
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # MLA (deepseek)
    mla_kv_lora: int = 0
    mla_rope_dim: int = 0
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # embeddings / io
    tie_embeddings: bool = False
    embed_inputs: bool = True       # False: frontend stub feeds embeddings
    pos_dims: int = 1               # 3 for M-RoPE
    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def n_layers(self) -> int:
        return sum(len(sb) * cnt for sb, cnt in self.segments)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline bookkeeping)."""
        d, hd = self.d_model, self.hd
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        total += d                                           # final norm
        for sb, cnt in self.segments:
            seg = 0
            for spec in sb:
                if spec.mixer == "attn":
                    if self.mla_kv_lora:
                        kvl, rd = self.mla_kv_lora, self.mla_rope_dim
                        seg += d * self.n_heads * (hd + rd)      # W_q
                        seg += d * (kvl + rd)                    # W_dkv, W_kpe
                        seg += kvl * self.n_heads * hd * 2       # W_uk, W_uv
                        seg += self.n_heads * hd * d             # W_o
                    else:
                        seg += d * self.n_heads * hd             # W_q
                        seg += 2 * d * self.n_kv_heads * hd      # W_k, W_v
                        seg += self.n_heads * hd * d             # W_o
                else:   # mamba2
                    din = self.d_inner
                    g = 2 * self.ssm_state                       # B and C
                    seg += d * (2 * din + g + self.ssm_heads)    # in_proj
                    seg += (din + g) * (self.ssm_conv + 1)       # conv w+b
                    seg += din * d                               # out_proj
                    seg += 3 * self.ssm_heads                    # A, D, dt_b
                    seg += din                                   # gated norm
                if spec.ffn == "dense":
                    seg += 3 * d * self.d_ff
                elif spec.ffn == "moe":
                    seg += d * self.moe_experts                  # router
                    seg += self.moe_experts * 3 * d * self.moe_d_ff
                    seg += self.moe_shared * 3 * d * self.moe_d_ff
                seg += d * (2 if spec.ffn != "none" else 1)      # norms
            total += seg * cnt
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.moe_experts:
            return self.param_count()
        full_e = self.moe_experts
        active_e = self.moe_top_k
        diff = 0
        for sb, cnt in self.segments:
            for spec in sb:
                if spec.ffn == "moe":
                    diff += cnt * (full_e - active_e) * 3 * \
                        self.d_model * self.moe_d_ff
        return self.param_count() - diff


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    data_axis: str = "data"
    model_axis: str = "model"
    pod_axis: Optional[str] = None      # set for the multi-pod mesh
    remat: str = "none"                 # "none" | "full" | "dots"
    scan_layers: bool = True
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # beyond-paper knobs (exercised in §Perf)
    shard_embed_data: bool = False      # activation-sharded embeddings
    dp_over_model: bool = False         # TP off: model axis becomes extra
                                        # data parallelism (right mapping
                                        # for small models on big meshes)
    seq_parallel: bool = False          # Megatron-SP: residual stream
                                        # sequence-sharded over model axis
                                        # between TP regions (AR -> RS+AG)
    flash_block: int = 512              # flash-attention KV block
    seq_shard_decode: bool = False      # shard long KV caches along seq


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    seq_len: int = 4096
    global_batch: int = 256
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    warmup: int = 100
    steps: int = 1000
    microbatch: int = 0                 # 0 = no accumulation
    grad_compress: str = "none"         # "none" | "int8"
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    seq_len: int = 32768                # KV cache length
    batch: int = 128
    prefill_chunk: int = 2048


_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (populates the registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
